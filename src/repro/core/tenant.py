"""Tenant & GuestDevice — the guest-side of the virtualization boundary.

``GuestDevice`` exposes the paper's MMD-layer interface operators
(§IV.C): ``open, close, read, write, get_info, set_irq, set_status,
reprogram`` — plus the memory operators the paper forwards to the VMM
(``alloc``/``free``, i.e. clCreateBuffer's path) and ``run``. Fidelity
means a tenant written against GuestDevice cannot tell whether ops are
mediated (FEV), passed through (BEV), or split (HYBRID): the VMM decides.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np


@dataclass
class GuestBuffer:
    handle: int
    nbytes: int
    shape: tuple
    dtype: str
    device_array: object = None


class GuestDevice:
    """The eight MMD operators + mediated memory ops. All calls delegate
    to the VMM, which enforces policy (FEV/BEV/HYBRID)."""

    def __init__(self, vmm, tenant):
        self._vmm = vmm
        self._tenant = tenant
        self._open = False

    # -- the 8 interface operators (paper §IV.C) -----------------------
    def open(self):
        self._vmm.op_open(self._tenant)
        self._open = True

    def close(self):
        self._vmm.op_close(self._tenant)
        self._open = False

    def read(self, handle: int) -> np.ndarray:
        return self._vmm.op_read(self._tenant, handle)

    def write(self, handle: int, data: np.ndarray, sharding=None):
        return self._vmm.op_write(self._tenant, handle, data, sharding)

    def get_info(self) -> dict:
        return self._vmm.op_get_info(self._tenant)

    def set_irq(self, handler: Callable):
        return self._vmm.op_set_irq(self._tenant, handler)

    def set_status(self, handler: Callable):
        return self._vmm.op_set_status(self._tenant, handler)

    def reprogram(self, request) -> object:
        """request: core.reconfig.ProgramRequest (or a pre-built Bitfile —
        which exercises the legality checks)."""
        return self._vmm.op_reprogram(self._tenant, request)

    # -- memory ops (forwarded to the VMM MMU, §IV.C) -----------------------
    def alloc(self, nbytes: int, shape=(), dtype="float32") -> int:
        return self._vmm.op_alloc(self._tenant, nbytes, shape, dtype)

    def free(self, handle: int):
        return self._vmm.op_free(self._tenant, handle)

    # -- data plane ----------------------------------------------------------
    def run(self, *args, **kw):
        return self._vmm.op_run(self._tenant, *args, **kw)

    # -- async data plane (scheduler submit() path; returns Futures) --------
    def run_async(self, *args, **kw):
        return self._vmm.op_run_async(self._tenant, *args, **kw)

    def write_async(self, handle: int, data: np.ndarray, sharding=None):
        return self._vmm.op_write_async(self._tenant, handle, data, sharding)

    def read_async(self, handle: int):
        return self._vmm.op_read_async(self._tenant, handle)


@dataclass
class Tenant:
    name: str
    vslice: object                      # core.vslice.VSlice
    pool: object                        # core.mmu.SegmentPool
    cq: object                          # core.shell.CompletionQueue
    device: GuestDevice = None
    buffers: Dict[int, GuestBuffer] = field(default_factory=dict)
    program: object = None              # LoadedProgram
    program_request: object = None
    state: dict = field(default_factory=dict)   # device-resident train state
    step: int = 0
    straggler_count: int = 0
    lock: threading.RLock = field(default_factory=threading.RLock)
    inflight: int = 0
    quiesced: bool = False
    _cv: threading.Condition = None

    def __post_init__(self):
        self._cv = threading.Condition(self.lock)

    # -- quiesce / freeze protocol (PR freeze signal analogue) -------------
    def enter_op(self):
        with self._cv:
            while self.quiesced:
                self._cv.wait()
            self.inflight += 1

    def exit_op(self):
        with self._cv:
            self.inflight -= 1
            self._cv.notify_all()

    class _Quiesce:
        def __init__(self, tenant):
            self.t = tenant

        def __enter__(self):
            with self.t._cv:
                self.t.quiesced = True
                while self.t.inflight > 0:
                    self.t._cv.wait()
            return self

        def __exit__(self, *exc):
            with self.t._cv:
                self.t.quiesced = False
                self.t._cv.notify_all()

    def quiesce(self):
        return Tenant._Quiesce(self)
