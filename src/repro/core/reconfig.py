"""Reconfiguration — the PR-controller analogue.

FPGA partial reconfiguration ↔ loading a freshly-compiled XLA executable
onto a vSlice. The mapping (DESIGN.md §2):

* bitfile            → ``Bitfile``: AOT-compiled executable + metadata
* CRC check          → content fingerprint verified at load
* decode + PR flow   → ``ProgramLoader.load`` with the freeze protocol
* bitfile↔PRR check  → slice binding: a Bitfile records the topology class
  and concrete slice fingerprint it was compiled for; the VMM refuses a
  load whose binding does not match the caller's slice (the paper's
  "user in VM0 reprograms PRR1" attack), while allowing *re-binding*
  across identical-topology slices via recompile-free device reassignment
  when permitted (warm migration).
* 2.5 s PCIe reconfig cost → XLA compile seconds; the ``CompileService``
  cache turns repeat loads into warm (milliseconds) reconfigurations.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.vslice import VSlice


class ReconfigError(Exception):
    pass


class LegalityError(ReconfigError):
    """Bitfile↔slice legality violation (isolation criterion)."""


@dataclass
class ProgramRequest:
    """What a tenant asks to have 'flashed': a named step program."""
    arch: str
    kind: str                    # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    reduced: bool = True
    opt_flags: Tuple = ()

    @property
    def program_key(self) -> str:
        h = hashlib.sha256(repr((self.arch, self.kind, self.seq_len,
                                 self.global_batch, self.reduced,
                                 self.opt_flags)).encode())
        return h.hexdigest()[:16]


@dataclass
class Bitfile:
    program_key: str
    topology_key: str            # e.g. "2x4" — shape class compatibility
    slice_fingerprint: str       # concrete binding
    compiled: object             # jax compiled executable
    abstract_args: tuple
    crc: str = ""
    compile_seconds: float = 0.0

    def __post_init__(self):
        if not self.crc:
            self.crc = self._compute_crc()

    def _compute_crc(self) -> str:
        h = hashlib.sha256(
            f"{self.program_key}|{self.topology_key}|"
            f"{self.slice_fingerprint}".encode())
        return h.hexdigest()[:16]

    def verify_crc(self) -> bool:
        return self.crc == self._compute_crc()


def weights_fingerprint(params) -> str:
    """Content hash of a weights pytree — leaf paths, shapes, dtypes and
    bytes. This is the ``slice_fingerprint`` of a weights-as-bitstream
    :class:`Bitfile` (model multiplexing): the CRC commits to the actual
    parameter bytes, so host-tier corruption of a swapped-out model is
    caught at swap-in, not silently served."""
    h = hashlib.blake2b(digest_size=8)
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclass
class LoadedProgram:
    bitfile: Bitfile
    slice_id: int

    def __call__(self, *args):
        return self.bitfile.compiled(*args)


class CompileService:
    """AOT lower+compile against a slice mesh, with an executable cache.

    Cache key = (program_key, topology_key): a program compiled once for a
    2×4 slice is a warm hit for *any* 2×4 slice (the paper's observation
    that PR bitfiles are only shell/region-compatible, made less painful
    by topology-class reuse)."""

    def __init__(self, step_builder: Optional[Callable] = None):
        # step_builder(cfg, mesh, cell) → (jitted, abstract_args)
        if step_builder is None:
            from repro.parallel.steps import build_step_for_cell
            step_builder = build_step_for_cell
        self._build = step_builder
        self.cache: Dict[Tuple[str, str], Bitfile] = {}
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()

    def compile(self, req: ProgramRequest, vslice: VSlice) -> Bitfile:
        key = (req.program_key, vslice.topology_key)
        with self._lock:
            if key in self.cache:
                self.hits += 1
                cached = self.cache[key]
                # re-bind to this concrete slice (warm reconfig)
                return Bitfile(cached.program_key, cached.topology_key,
                               vslice.fingerprint, cached.compiled,
                               cached.abstract_args,
                               compile_seconds=0.0)
        from repro.configs import get_config
        from repro.configs.base import ShapeCell
        cfg = get_config(req.arch, reduced=req.reduced)
        cell = ShapeCell("custom", req.seq_len, req.global_batch,
                         req.kind)
        t0 = time.perf_counter()
        mesh = getattr(vslice, "mesh", None)
        from repro.compat import set_mesh_ctx
        ctx = set_mesh_ctx(mesh)
        with ctx:
            jitted, abstract_args = self._build(cfg, mesh, cell)
            lowered = jitted.lower(*abstract_args)
            compiled = lowered.compile()
        dt = max(time.perf_counter() - t0, 1e-9)
        bf = Bitfile(req.program_key, vslice.topology_key,
                     vslice.fingerprint, compiled, abstract_args,
                     compile_seconds=dt)
        with self._lock:
            self.misses += 1
            self.cache[key] = bf
        return bf


class ProgramLoader:
    """The PR flow: legality checks + freeze protocol + load."""

    def __init__(self, auditor=None):
        self.loaded: Dict[int, LoadedProgram] = {}   # slice_id → program
        self.auditor = auditor
        self.reconfigs = 0
        self.crc_checks = 0
        self.crc_failures = 0

    def verify_bitfile(self, bitfile: Bitfile, owner: str = "?"):
        """CRC-only verification (counted) — every load AND every
        model-registry swap-in goes through here, so a corrupted
        bitstream never reaches a slice or a serving engine silently."""
        self.crc_checks += 1
        if not bitfile.verify_crc():
            self.crc_failures += 1
            if self.auditor:
                self.auditor.record("bitfile_crc_fail", owner, {})
            raise LegalityError("bitfile CRC check failed")

    def validate(self, bitfile: Bitfile, vslice: VSlice, owner: str = "?"):
        self.verify_bitfile(bitfile, owner)
        if bitfile.topology_key != vslice.topology_key:
            if self.auditor:
                self.auditor.record("bitfile_topology_mismatch", owner,
                                    {"bitfile": bitfile.topology_key,
                                     "slice": vslice.topology_key})
            raise LegalityError(
                f"bitfile for topology {bitfile.topology_key} cannot load "
                f"on slice {vslice.topology_key}")
        if bitfile.slice_fingerprint != vslice.fingerprint:
            if self.auditor:
                self.auditor.record("cross_slice_reprogram", owner,
                                    {"bitfile_slice":
                                     bitfile.slice_fingerprint,
                                     "target_slice": vslice.fingerprint})
            raise LegalityError(
                "bitfile is bound to a different slice (the paper's "
                "cross-PRR reprogram attack) — VMM must re-bind it")

    def load(self, bitfile: Bitfile, vslice: VSlice, quiesce: Callable,
             owner: str = "?") -> LoadedProgram:
        self.validate(bitfile, vslice, owner)
        # freeze protocol: drain + block the slice while swapping programs
        with quiesce():
            prog = LoadedProgram(bitfile, vslice.slice_id)
            self.loaded[vslice.slice_id] = prog
            self.reconfigs += 1
        return prog

    def unload(self, vslice: VSlice):
        self.loaded.pop(vslice.slice_id, None)
