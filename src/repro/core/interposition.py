"""Interposition — recording and replaying the VM↔device interaction.

The paper: "Interposition is the ability of recording accesses between the
VMs and physical device with software … empowers VM live migration,
checkpoint and restore." Here:

* ``OpLog`` — every mediated operation is appended (FEV: all ops;
  HYBRID: control plane always, data plane sampled). Queryable for the
  criteria report.
* ``TenantCheckpointer`` — snapshot/restore of a tenant's device-resident
  state (params / optimizer / step / loaded-program identity) through the
  checkpointing substrate; restore re-shards for the *target* slice, which
  is what makes live migration and elastic re-slicing possible.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax

from repro.checkpointing import restore as ckpt_restore
from repro.checkpointing import save as ckpt_save


@dataclass
class OpRecord:
    tenant: str
    op: str
    detail: dict
    t_start: float
    t_end: float = 0.0

    @property
    def duration_ms(self):
        return (self.t_end - self.t_start) * 1e3


class OpLog:
    def __init__(self, sample_data_plane: float = 1.0):
        self.records: List[OpRecord] = []
        self.sample_data_plane = sample_data_plane
        self._n_data_ops = 0
        self._lock = threading.Lock()

    CONTROL_OPS = {"open", "close", "alloc", "free", "reprogram",
                   "checkpoint", "restore", "migrate", "set_irq",
                   "set_status", "get_info", "admit", "evict"}

    def begin(self, tenant: str, op: str, detail=None) -> Optional[OpRecord]:
        if op not in self.CONTROL_OPS:
            with self._lock:
                self._n_data_ops += 1
                if self.sample_data_plane < 1.0 and (
                        self._n_data_ops * self.sample_data_plane) % 1.0 \
                        >= self.sample_data_plane:
                    return None
        r = OpRecord(tenant, op, detail or {}, time.perf_counter())
        with self._lock:
            self.records.append(r)
        return r

    def end(self, rec: Optional[OpRecord]):
        if rec is not None:
            rec.t_end = time.perf_counter()

    def query(self, tenant=None, op=None) -> List[OpRecord]:
        with self._lock:
            return [r for r in self.records
                    if (tenant is None or r.tenant == tenant)
                    and (op is None or r.op == op)]

    def completeness(self) -> float:
        """Fraction of issued data-plane ops that were recorded."""
        with self._lock:
            n_logged = sum(1 for r in self.records
                           if r.op not in self.CONTROL_OPS)
            return n_logged / max(self._n_data_ops, 1)

    def op_latency_stats(self) -> dict:
        """Per-op latency rollup from the ``perf_counter`` stamps every
        record already carries: ``{op: {count, mean_ms, p50_ms,
        p95_ms}}`` over completed records. This is the registry surface
        ``VMM.stats()["ops"]`` exposes (and fig6b reads) instead of
        benchmarks re-measuring with private timers."""
        with self._lock:
            by_op = {}
            for r in self.records:
                if r.t_end > 0.0:
                    by_op.setdefault(r.op, []).append(r.duration_ms)
        out = {}
        for op, ds in by_op.items():
            ds.sort()
            n = len(ds)
            out[op] = {
                "count": n,
                "mean_ms": sum(ds) / n,
                "p50_ms": ds[n // 2],
                "p95_ms": ds[min(int(0.95 * (n - 1)), n - 1)],
            }
        return out


class TenantCheckpointer:
    """Snapshot / restore of tenant device state (incl. re-sharding)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def path(self, tenant_name: str) -> str:
        return os.path.join(self.root, tenant_name)

    def snapshot(self, tenant_name: str, step: int, state_tree,
                 meta: dict) -> str:
        return ckpt_save(self.path(tenant_name), step, state_tree, meta)

    def restore(self, tenant_name: str, template, shardings_tree=None):
        from repro.checkpointing import latest
        d = latest(self.path(tenant_name))
        if d is None:
            raise FileNotFoundError(
                f"no checkpoint for tenant {tenant_name}")
        return ckpt_restore(d, template, shardings_tree)
