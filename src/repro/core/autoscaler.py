"""IRQ-driven elastic autoscaler — the control loop the paper's §IV
degradation interrupts were built for.

The data-plane scheduler raises ``IRQ_DEGRADED`` on a tenant's
completion queue when its backlog stays above the high watermark
(``queue_buildup``) or an op blows its EWMA deadline (``straggler``).
Until now nothing consumed those interrupts; this module closes the
loop: sustained pressure, filtered through hysteresis and a cooldown,
triggers a slice resize through the elastic re-slicing primitive
(:func:`repro.core.elastic.resize`, i.e. checkpoint → re-floorplan →
re-bind → restore), and a sustained calm period shrinks the tenant back
toward its baseline shape.

Design points:

* **Event subscription, decision polling.** The IRQ handler only
  records timestamped pressure events (handlers run on whatever thread
  raised the event — a submitter or the plane worker — so they must
  stay O(1)). Scaling decisions happen in :meth:`poll`, either driven
  explicitly (tests, serving loops) or by the optional background
  thread (:meth:`start`).
* **Hysteresis.** A resize requires ``sustain`` pressure events inside
  ``window_s``; after any action the tenant is immune for
  ``cooldown_s``; scale-down requires ``calm_s`` with no events and
  only ever retraces the grow history (never below baseline).
* **Failure is data.** A grow that cannot be placed first tries
  :func:`~repro.core.elastic.defragment`; if the retry still fails the
  action is recorded as ``grow_blocked`` (and the cooldown still
  applies, so a full floorplan is not hammered).

All actions are visible in ``VMM.stats()["autoscaler"]``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.lock_watchdog import note_callback
from repro.core.elastic import defragment, resize
from repro.core.scheduler import IRQ_DEGRADED
from repro.core.vmm import AdmissionError

#: IRQ_DEGRADED event kinds that count as scaling pressure. Other kinds
#: on the same line (e.g. ``slice_failed``) have their own consumers.
PRESSURE_KINDS = ("queue_buildup", "straggler")


@dataclass
class _Watch:
    tenant: object
    baseline: Tuple[int, int]
    state_template: object = None
    shardings_fn: object = None
    events: deque = field(default_factory=lambda: deque(maxlen=256))
    history: List[Tuple[int, int]] = field(default_factory=list)
    # -inf: a fresh watch is neither cooling down nor recently pressured
    last_event: float = float("-inf")
    last_action: float = float("-inf")


class Autoscaler:
    """Subscribe to degradation IRQs; resize slices under sustained
    pressure. One instance per VMM (it registers itself so
    ``VMM.stats()`` surfaces its action log)."""

    def __init__(self, vmm, sustain: int = 3, window_s: float = 2.0,
                 cooldown_s: float = 5.0, calm_s: float = 10.0,
                 max_devices: Optional[int] = None,
                 scale_down: bool = True,
                 time_fn: Callable[[], float] = time.monotonic,
                 swap_cb: Optional[Callable[[str], bool]] = None):
        self.vmm = vmm
        self.sustain = sustain
        self.window_s = window_s
        self.cooldown_s = cooldown_s
        self.calm_s = calm_s
        self.max_devices = max_devices
        self.scale_down = scale_down
        self.time_fn = time_fn
        # swap-before-deny at the capacity layer: when a grow cannot be
        # placed even after defragmentation, ``swap_cb(tenant_name)``
        # asks the KV swap tier to shed device pressure to host memory;
        # True turns ``grow_blocked`` into ``swap_relief``.
        self.swap_cb = swap_cb
        self.actions: deque = deque(maxlen=256)  # guarded-by: _lock
        self._watched: Dict[str, _Watch] = {}    # guarded-by: _lock
        # tenants whose cq has our handler
        self._hooked: set = set()                # guarded-by: _lock
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        vmm.autoscaler = self

    # -- subscription ---------------------------------------------------
    def watch(self, tenant, state_template=None, shardings_fn=None):
        """Start consuming ``tenant``'s degradation IRQs. Chains any
        previously installed IRQ_DEGRADED handler. Idempotent: a
        re-watch (e.g. to refresh the state template) replaces the
        watch record without chaining our own handler into itself."""
        w = _Watch(tenant=tenant, baseline=tuple(tenant.vslice.spec.shape),
                   state_template=state_template, shardings_fn=shardings_fn)
        with self._lock:
            self._watched[tenant.name] = w
            hook = tenant.name not in self._hooked
            if hook:
                self._hooked.add(tenant.name)
        if hook:
            prev = tenant.cq.handlers.get(IRQ_DEGRADED)

            def handler(ev, _name=tenant.name, _prev=prev):
                self._on_irq(_name, ev)   # no-op if no longer watched
                if _prev is not None:
                    _prev(ev)

            tenant.cq.set_irq(IRQ_DEGRADED, handler)
        return w

    def unwatch(self, name: str):
        with self._lock:
            self._watched.pop(name, None)

    def _on_irq(self, name: str, ev):
        if ev.kind not in PRESSURE_KINDS:
            return
        now = self.time_fn()
        with self._lock:
            w = self._watched.get(name)
            if w is None:
                return
            w.events.append((now, ev.kind))
            w.last_event = now

    # -- control loop ---------------------------------------------------
    def poll(self, now: Optional[float] = None) -> List[dict]:
        """Evaluate every watched tenant once; perform at most one
        scaling action per tenant. Returns the actions taken."""
        now = self.time_fn() if now is None else now
        taken = []
        with self._lock:
            watches = list(self._watched.values())
        for w in watches:
            with self._lock:
                while w.events and now - w.events[0][0] > self.window_s:
                    w.events.popleft()
                n_events = len(w.events)
                last_event, last_action = w.last_event, w.last_action
            if now - last_action < self.cooldown_s:
                continue
            act = None
            try:
                if n_events >= self.sustain:
                    act = self._grow(w, now, n_events)
                elif (self.scale_down and w.history
                        and now - last_event >= self.calm_s):
                    act = self._shrink(w, now)
            except Exception as exc:       # noqa: BLE001
                # a resize can fail beyond AdmissionError (re-bind,
                # checkpoint I/O, ...) — record it and keep the control
                # loop alive rather than silently killing the thread
                act = self._record(w, now, action="error",
                                   error=f"{type(exc).__name__}: {exc}")
            if act is not None:
                taken.append(act)
        return taken

    def _candidates(self, shape: Tuple[int, int]) -> List[Tuple[int, int]]:
        r, c = shape
        fp = self.vmm.floorplanner
        cap = self.max_devices or fp.rows * fp.cols
        cands = [(r, 2 * c), (2 * r, c)]
        return [(nr, nc) for nr, nc in cands
                if nr <= fp.rows and nc <= fp.cols and nr * nc <= cap]

    def _resize(self, w: _Watch, shape: Tuple[int, int]) -> bool:
        try:
            resize(self.vmm, w.tenant, shape,
                   state_template=w.state_template,
                   shardings_fn=w.shardings_fn)
            return True
        except AdmissionError:
            return False

    def _record(self, w: _Watch, now: float, **fields) -> dict:
        act = {"t": now, "tenant": w.tenant.name, **fields}
        with self._lock:
            self.actions.append(act)
            w.last_action = now
            w.events.clear()
        obs = getattr(self.vmm, "obs", None)
        if obs is not None and obs.enabled:
            obs.count("autoscaler_actions_total", tenant=w.tenant.name,
                      action=fields.get("action", "unknown"))
            # grow_blocked is a flight-recorder trigger — the dump shows
            # the IRQ pressure that led to the unplaceable resize
            obs.flight_record(w.tenant.name, fields.get("action", "action"),
                              {k: v for k, v in act.items() if k != "tenant"})
        return act

    def _grow(self, w: _Watch, now: float, n_events: int) -> Optional[dict]:
        old = tuple(w.tenant.vslice.spec.shape)
        cands = self._candidates(old)
        if not cands:
            if self.swap_cb is not None:
                note_callback("autoscaler.swap_cb")
            if self.swap_cb is not None and self.swap_cb(w.tenant.name):
                return self._record(w, now, action="swap_relief", frm=old,
                                    to=None, pressure_events=n_events,
                                    reason="at capacity")
            return self._record(w, now, action="grow_blocked", frm=old,
                                to=None, pressure_events=n_events,
                                reason="at capacity")
        for shape in cands:
            if self._resize(w, shape):
                w.history.append(old)
                return self._record(w, now, action="grow", frm=old,
                                    to=shape, pressure_events=n_events)
        # nothing placed: defragment the floorplan and retry the
        # preferred candidate once
        defragment(self.vmm)
        if self._resize(w, cands[0]):
            w.history.append(old)
            return self._record(w, now, action="grow", frm=old,
                                to=cands[0], pressure_events=n_events,
                                defragmented=True)
        if self.swap_cb is not None:
            note_callback("autoscaler.swap_cb")
        if self.swap_cb is not None and self.swap_cb(w.tenant.name):
            # device capacity is exhausted but the KV swap tier absorbed
            # the pressure (a victim slot parked to host memory) — the
            # tenant keeps serving instead of waiting out the block
            return self._record(w, now, action="swap_relief", frm=old,
                                to=cands[0], pressure_events=n_events,
                                reason="swapped under capacity block")
        return self._record(w, now, action="grow_blocked", frm=old,
                            to=cands[0], pressure_events=n_events,
                            reason="no slice even after defrag")

    def _shrink(self, w: _Watch, now: float) -> Optional[dict]:
        old = tuple(w.tenant.vslice.spec.shape)
        target = w.history[-1]
        if self._resize(w, target):
            w.history.pop()
            return self._record(w, now, action="shrink", frm=old,
                                to=target)
        return self._record(w, now, action="shrink_blocked", frm=old,
                            to=target)

    # -- background driver ----------------------------------------------
    def start(self, interval_s: float = 0.25):
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self.poll()
                self._stop.wait(interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None

    # -- introspection ---------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "watched": {
                    n: {"baseline": list(w.baseline),
                        "shape": list(w.tenant.vslice.spec.shape),
                        "pending_events": len(w.events),
                        "grows_outstanding": len(w.history)}
                    for n, w in self._watched.items()},
                "actions": [dict(a) for a in self.actions],
            }
