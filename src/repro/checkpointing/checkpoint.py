"""Sharded, manifest-based checkpointing with async save and elastic
(re-sharded) restore.

Layout of a checkpoint directory::

    <root>/step_000120/
        manifest.json          # key → {file, shape, dtype}, step, meta
        <leafkey>.npy          # one file per pytree leaf
        _COMMITTED             # written last — crash-safe commit marker

Restore can target a *different* mesh/sharding than the one that saved
(elastic scaling / live migration): leaves are read on host and
``jax.device_put`` against the target shardings. Async saves run on a
worker thread so the train loop overlaps checkpoint I/O with compute
(fault-tolerance requirement from the scale deliverable; also the
*interposition* machinery of the paper — VM checkpoint/restore).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Optional

import jax
import numpy as np

_COMMIT = "_COMMITTED"


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts) or "root"


def _flatten(tree):
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_leaf_key(path), leaf) for path, leaf in leaves]


def save(root: str, step: int, tree, meta: Optional[dict] = None) -> str:
    """Synchronous sharded save. Returns the checkpoint directory."""
    d = os.path.join(root, f"step_{step:08d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in _flatten(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        true_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or true_dtype in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            # numpy can't natively persist ml_dtypes — store raw bits
            arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": true_dtype}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    return d


def restore(ckpt_dir: str, template=None, shardings_tree=None):
    """Restore a checkpoint directory → (step, tree, meta).

    ``template`` (a pytree of like-structured leaves / SDS) defines the
    output structure; without it a flat {key: array} dict is returned.
    ``shardings_tree`` re-shards leaves for the target mesh (elastic).
    """
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes
    arrays = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(ckpt_dir, info["file"]))
        want = info["dtype"]
        if str(arr.dtype) != want:          # bit-stored ml_dtypes leaf
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        arrays[key] = arr
    if template is None:
        return manifest["step"], arrays, manifest["meta"]
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings_tree)
                    if shardings_tree is not None else [None] * len(leaves))
    out = []
    for (path, tmpl), shard in zip(leaves, shard_leaves):
        key = _leaf_key(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key].astype(tmpl.dtype)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {tmpl.shape}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return manifest["step"], tree, manifest["meta"]


def latest(root: str) -> Optional[str]:
    if not os.path.isdir(root):
        return None
    best = None
    for name in sorted(os.listdir(root)):
        d = os.path.join(root, name)
        if (name.startswith("step_") and
                os.path.exists(os.path.join(d, _COMMIT))):
            best = d
    return best


class CheckpointManager:
    """Interval + retention + async-save management."""

    def __init__(self, root: str, save_interval: int = 100,
                 keep_n: int = 3, async_save: bool = True):
        self.root = root
        self.save_interval = save_interval
        self.keep_n = keep_n
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def save(self, step: int, tree, meta=None, block=False):
        # device_get on the caller thread (consistent snapshot), I/O async
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _do():
            p = save(self.root, step, host_tree, meta)
            self._gc()
            return p

        if self.async_save and not block:
            self.wait()
            with self._lock:
                self._pending = self._pool.submit(_do)
            return self._pending
        return _do()

    def wait(self):
        with self._lock:
            p = self._pending
        if p is not None:
            p.result()

    def restore_latest(self, template=None, shardings_tree=None):
        d = latest(self.root)
        if d is None:
            return None
        return restore(d, template, shardings_tree)

    def _gc(self):
        names = [n for n in sorted(os.listdir(self.root))
                 if n.startswith("step_")]
        for n in names[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.root, n), ignore_errors=True)
