from repro.checkpointing.checkpoint import (CheckpointManager, latest,
                                            restore, save)

__all__ = ["CheckpointManager", "latest", "restore", "save"]
