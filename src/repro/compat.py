"""Shims for jax API drift so the runtime works on older jax releases.

* ``shard_map`` — promoted to ``jax.shard_map`` (with ``check_vma``) in
  newer jax; older releases only have
  ``jax.experimental.shard_map.shard_map`` (with ``check_rep``).
* ``set_mesh_ctx`` — newer jax exposes ``jax.set_mesh``; older
  releases use the ``Mesh`` object itself as the resource-env context
  manager.
"""
from __future__ import annotations

import contextlib

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                         # jax < 0.6
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)


def set_mesh_ctx(mesh):
    """Context manager making ``mesh`` the ambient mesh (no-op for
    ``None``): ``jax.set_mesh`` on newer jax, the ``Mesh`` object
    itself on older releases."""
    if mesh is None:
        return contextlib.nullcontext()
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def element_block_spec(block_shape, index_map):
    """BlockSpec whose index_map yields *element* offsets (overlapping
    halo windows): ``pl.Element`` dims on newer jax, the ``Unblocked``
    indexing mode on older releases."""
    from jax.experimental import pallas as pl
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(n) for n in block_shape),
                            index_map)
    return pl.BlockSpec(block_shape, index_map,
                        indexing_mode=pl.Unblocked())


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` on newer jax, ``TPUCompilerParams`` on
    older releases — keyword surface is shared."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)
