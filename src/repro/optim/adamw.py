"""AdamW with warmup-cosine schedule, global-norm clipping, gradient
accumulation (with optional bf16 gradient compression — a distributed-
optimization trick: microbatch gradients are cast to bf16 before the
cross-replica accumulation/reduction, halving all-reduce bytes).

No optax in this environment — this is the full substrate, pytree-native,
eval_shape-compatible for the AOT dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    micro_steps: int = 1            # gradient accumulation factor
    grad_compress: bool = False     # bf16-compressed accumulation/reduction
    state_dtype: str = "float32"    # m/v dtype ("bfloat16" for 1T models)


def schedule(opt: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = opt.peak_lr * step / max(opt.warmup_steps, 1)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.decay_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = opt.min_lr + 0.5 * (opt.peak_lr - opt.min_lr) * (
        1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < opt.warmup_steps, warm, cos)


def init(opt: OptConfig, params):
    dt = jnp.dtype(opt.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path):
    """No weight decay on norms/biases/scalars (standard practice)."""
    names = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
    leaf = str(names[-1]) if names else ""
    return not any(s in leaf for s in
                   ("scale", "bias", "mu", "lam", "decay_base", "bonus"))


def update(opt: OptConfig, grads, state, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(opt, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = opt.beta1, opt.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(opt.state_dtype)

    flat_g, treedef = jax.tree_util.tree_flatten_with_path(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)

    new_p, new_m, new_v = [], [], []
    for (path, g), m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + opt.eps)
        if opt.weight_decay and _decay_mask(path):
            upd = upd + opt.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m32.astype(sdt))
        new_v.append(v32.astype(sdt))

    td = jax.tree.structure(params)
    out_params = jax.tree.unflatten(td, new_p)
    new_state = {"m": jax.tree.unflatten(td, new_m),
                 "v": jax.tree.unflatten(td, new_v),
                 "step": step}
    return out_params, new_state, {"lr": lr, "grad_norm": gnorm}


def make_train_step(model, opt: OptConfig):
    """Builds the donated, accumulating train step (pjit-able)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if opt.micro_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            n = opt.micro_steps
            gdt = jnp.bfloat16 if opt.grad_compress else jnp.float32

            def split(x):
                b = x.shape[0]
                return x.reshape((n, b // n) + x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params)

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(gdt), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (grads, loss), _ = jax.lax.scan(
                acc_step, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n, grads)
            loss = loss / n
            metrics = {}
        new_params, new_state, om = update(opt, grads, opt_state, params)
        out = {"loss": loss, **om}
        out.update({k: v for k, v in metrics.items() if k != "n_tok"})
        return new_params, new_state, out

    return train_step
