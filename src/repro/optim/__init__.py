from repro.optim.adamw import (OptConfig, global_norm, init,
                               make_train_step, schedule, update)

__all__ = ["OptConfig", "global_norm", "init", "make_train_step",
           "schedule", "update"]
